"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig8] [--trajectory]
    PYTHONPATH=src python -m benchmarks.run --check

Prints ``name,value,derived`` CSV (value is µs for *_us rows, else a
dimensionless/derived quantity per the row's note).

``--trajectory`` is the perf-regression gate (ROADMAP "Real-hardware
readiness", grown from the PR-7 first cut): before each module runs, the
previous ``BENCH_*.json`` payloads are snapshotted (the committed
version via ``git show`` when one exists, else the working-tree file
from the last run); the module then runs ``--repeats`` times so every
numeric leaf yields a *sample set*, and each leaf is compared against
its previous value with a noise-aware band — the larger of a
per-metric-kind relative floor and ``MAD_Z`` normalized median absolute
deviations of the fresh samples. Each leaf is classified by the
``COVERAGE`` registry (kernel, metric kind, direction); a move beyond
the band in a leaf's *bad* direction is a confirmed ``REGRESSION`` and
the process exits nonzero. Leaves present on one side only print as
``NEW`` / ``GONE`` rows instead of being dropped. A leaf no registry
pattern claims is a coverage failure (also nonzero): every benchmark
number must say which kernel it measures.

``--check`` runs the gate's static half only — BENCH coverage plus
``kernels/autotune.py`` tuning-table validation — with no benchmarks and
no sweep; ``scripts/ci_tier1.sh`` runs this so a broken table or an
unmapped BENCH leaf fails fast.
"""

import argparse
import fnmatch
import glob
import json
import os
import subprocess
import sys

# noise model: band = max(rel_floor(kind) * |prev|, MAD_Z * 1.4826 * MAD)
REL_FLOOR = 0.05          # deterministic counts/ratios: any real move flags
REL_FLOOR_TIME = 0.35     # wall-clock leaves jitter hard on shared CPUs
MAD_Z = 5.0
DEFAULT_REPEATS = 3
_TIME_KINDS = ("time", "throughput")

# ---------------------------------------------------------------------------
# Per-kernel coverage registry: every numeric leaf of every BENCH file must
# match a pattern (first match wins). Fields: (pattern, kernel, kind,
# direction); direction "lower"/"higher" = which way is GOOD, "info" =
# workload descriptor, reported but never gated.
# ---------------------------------------------------------------------------
COVERAGE = {
    "BENCH_prefix.json": [
        ("trace.*", "prefill", "workload", "info"),
        ("cache_*.ttft_*", "prefill", "time", "lower"),
        ("cache_*.wall_s", "prefill", "time", "lower"),
        ("cache_*.tokens_per_s", "prefill", "throughput", "higher"),
        ("cache_*.prefill_tokens_computed", "prefill", "count", "lower"),
        ("cache_*.prefill_tokens_served", "prefill", "count", "info"),
        ("cache_on.prefix_hits", "prefill", "count", "higher"),
        ("cache_on.prefix_hit_tokens", "prefill", "count", "higher"),
        ("ttft_hit_vs_cache_off_ratio", "prefill", "ratio", "lower"),
        ("ttft_per_request.cached_len.*", "prefill", "count", "info"),
        ("ttft_per_request.*", "prefill", "time", "info"),
    ],
    "BENCH_spec.json": [
        ("trace.*", "decode", "workload", "info"),
        ("arms.*.draft_layers", "decode", "workload", "info"),
        ("arms.*.draft_k", "decode", "workload", "info"),
        ("arms.*.wall_s", "decode", "time", "lower"),
        ("arms.*.accept_rate", "decode", "ratio", "higher"),
        ("arms.*.decoded_tokens", "decode", "count", "info"),
        ("arms.*.full_launches_per_decoded", "decode", "ratio", "lower"),
        ("arms.*.full_launches_saved_vs_baseline", "decode", "count",
         "higher"),
        ("arms.*.full_launches", "decode", "count", "lower"),
        ("arms.*.draft_launches_per_decoded", "decode", "ratio", "info"),
        ("arms.*.spec_rounds", "decode", "count", "info"),
        ("arms.*.tokens_per_verify", "decode", "ratio", "higher"),
        ("arms.*.model_step_equiv_per_decoded", "decode", "ratio", "lower"),
    ],
    "BENCH_proj.json": [
        ("proj_dispatches_*", "qlinear", "count", "lower"),
        ("proj_layer_step_*_us", "qlinear", "time", "lower"),
        ("shapes.*", "qlinear", "workload", "info"),
    ],
    "BENCH_http.json": [
        ("trace.*", "scheduler", "workload", "info"),
        ("http.ttft_*_ms", "scheduler", "time", "lower"),
        ("http.itl_*_ms", "scheduler", "time", "lower"),
        ("http.wall_s", "scheduler", "time", "lower"),
        ("http.tokens_per_s", "scheduler", "throughput", "higher"),
        ("http.requests_ok", "scheduler", "count", "info"),
        ("http.sse_frames", "scheduler", "count", "info"),
        ("server.*", "scheduler", "count", "info"),
    ],
    "BENCH_faults.json": [
        ("trace.*", "scheduler", "workload", "info"),
        ("recovery.wall_*_s", "scheduler", "time", "lower"),
        ("recovery.fault_events", "scheduler", "count", "info"),
        ("recovery.fault_recoveries", "scheduler", "count", "higher"),
        ("recovery.fault_finishes", "scheduler", "count", "lower"),
        # wall-delta clamped at 0: too noisy to gate, report-only
        ("recovery.recovery_ms_per_event", "scheduler", "time", "info"),
        ("recovery.retry_step_ms", "scheduler", "time", "lower"),
        ("overload.shed_count", "scheduler", "count", "info"),
        ("overload.shed_rate", "scheduler", "ratio", "info"),
        ("overload.queue_depth_peak", "scheduler", "count", "info"),
        ("deadline.deadline_count", "scheduler", "count", "info"),
        ("deadline.deadline_hit_ratio", "scheduler", "ratio", "higher"),
    ],
}


def _numeric_leaves(obj, prefix=""):
    """Flatten a JSON payload to {dotted.path: float} over numeric leaves."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_numeric_leaves(v, f"{prefix}{k}."))
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            out.update(_numeric_leaves(v, f"{prefix}{i}."))
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        out[prefix.rstrip(".")] = float(obj)
    return out


def _leaf_rule(path: str, key: str):
    """(kernel, kind, direction) for a BENCH leaf, or None (uncovered)."""
    for pattern, kernel, kind, direction in COVERAGE.get(path, ()):
        if fnmatch.fnmatchcase(key, pattern):
            return kernel, kind, direction
    return None


def _coverage_problems(payloads: dict) -> list:
    """Every leaf of every payload must map to a declared kernel+metric."""
    problems = []
    for path in sorted(payloads):
        if path not in COVERAGE:
            problems.append(f"{path}: no coverage declared")
            continue
        for key in sorted(payloads[path]):
            if _leaf_rule(path, key) is None:
                problems.append(f"{path}:{key} matches no coverage pattern")
    return problems


def _bench_snapshot(paths=None):
    """{filename: numeric leaves} of every BENCH_*.json — the committed
    version when git has one (the run-over-run reference), else the
    working-tree file left by the previous run."""
    snap = {}
    for path in sorted(paths if paths is not None
                       else glob.glob("BENCH_*.json")):
        text = None
        try:
            text = subprocess.run(
                ["git", "show", f"HEAD:{path}"], capture_output=True,
                text=True, check=True).stdout
        except (subprocess.CalledProcessError, OSError):
            pass
        if text is None:
            try:
                with open(path) as fh:
                    text = fh.read()
            except OSError:
                continue
        try:
            snap[path] = _numeric_leaves(json.loads(text))
        except (ValueError, TypeError):
            continue
    return snap


def _read_bench(paths=None) -> dict:
    """{filename: numeric leaves} of the working-tree BENCH files."""
    out = {}
    for path in sorted(paths if paths is not None
                       else glob.glob("BENCH_*.json")):
        try:
            with open(path) as fh:
                out[path] = _numeric_leaves(json.load(fh))
        except (OSError, ValueError):
            continue
    return out


def _median(xs):
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def _noise_band(prev: float, samples, kind: str) -> float:
    """Absolute half-width of the acceptance band around ``prev``: the
    larger of the kind's relative floor and MAD_Z normalized MADs of the
    fresh samples (repeat-to-repeat noise measured this run)."""
    floor = REL_FLOOR_TIME if kind in _TIME_KINDS else REL_FLOOR
    med = _median(samples)
    sigma = 1.4826 * _median([abs(x - med) for x in samples])
    return max(floor * abs(prev), MAD_Z * sigma)


def _compare_leaf(prev: float, samples, kind: str, direction: str):
    """One leaf's verdict: (delta_str, status) where status is
    'ok' | 'improved' | 'regression' | 'moved' (info direction)."""
    med = _median(samples)
    if med == prev:
        return None
    band = _noise_band(prev, samples, kind)
    rel = (med - prev) / max(abs(prev), 1e-12)
    delta = f"{prev:.4g} -> {med:.4g} ({rel * 100:+.1f}%)"
    if abs(med - prev) <= band:
        return delta, "ok"
    if direction == "info":
        return delta, "moved"
    bad = med > prev if direction == "lower" else med < prev
    return delta, ("regression" if bad else "improved")


def _trajectory_report(before: dict, samples_by_path: dict) -> int:
    """Diff fresh sample sets against ``before``; print verdicts, return
    the count of confirmed regressions."""
    regressions = 0
    for path in sorted(samples_by_path):
        samples = samples_by_path[path]
        prev = before.get(path)
        if prev is None:
            print(f"# trajectory: {path} is new (no previous run)")
            continue
        keys = sorted(set(prev) | set(samples))
        for key in keys:
            if key not in samples:
                print(f"# trajectory: {path}:{key} GONE "
                      f"(was {prev[key]:.4g})")
                continue
            if key not in prev:
                print(f"# trajectory: {path}:{key} NEW = "
                      f"{_median(samples[key]):.4g}")
                continue
            rule = _leaf_rule(path, key)
            kind, direction = (rule[1], rule[2]) if rule else ("count",
                                                               "info")
            verdict = _compare_leaf(prev[key], samples[key], kind,
                                    direction)
            if verdict is None:
                continue
            delta, status = verdict
            if status == "regression":
                regressions += 1
                print(f"# trajectory: {path}:{key} {delta} REGRESSION")
            elif status == "improved":
                print(f"# trajectory: {path}:{key} {delta} improved")
            elif status == "moved":
                print(f"# trajectory: {path}:{key} {delta}")
    return regressions


def _check(paths=None) -> int:
    """Static gate: BENCH coverage + tuning-table validity. No benchmarks."""
    problems = _coverage_problems(_read_bench(paths))
    try:
        from repro.kernels import autotune
        problems += [f"tuning table: {p}" for p in autotune.validate_table()]
    except ImportError as e:
        problems.append(f"tuning table: autotune unimportable ({e!r})")
    for p in problems:
        print(f"# check: {p}")
    print(f"# check: {'FAIL' if problems else 'OK'} "
          f"({len(problems)} problem(s))")
    return 1 if problems else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module name")
    ap.add_argument("--trajectory", action="store_true",
                    help="run each module --repeats times and gate every "
                         "BENCH_*.json leaf against the previous run with "
                         "a median + MAD noise band; exits nonzero on a "
                         "confirmed regression or a coverage hole")
    ap.add_argument("--repeats", type=int, default=DEFAULT_REPEATS,
                    help="trajectory sample count per module "
                         f"(default {DEFAULT_REPEATS})")
    ap.add_argument("--check", action="store_true",
                    help="static gate only: BENCH coverage + tuning-table "
                         "validation, no benchmarks")
    args = ap.parse_args()

    if args.check:
        sys.exit(_check())

    from benchmarks import (fig8_lop, fig9_schedule, http_serving,
                            kernels_micro, prefill_interleave, prefix_cache,
                            robustness, spec_decode, table1_e2e)
    modules = [
        ("fig8_lop", fig8_lop),
        ("fig9_schedule", fig9_schedule),
        ("table1_e2e", table1_e2e),
        ("kernels_micro", kernels_micro),
        ("prefill_interleave", prefill_interleave),
        ("prefix_cache", prefix_cache),
        ("spec_decode", spec_decode),
        ("robustness", robustness),
        ("http_serving", http_serving),
    ]
    print("name,value,derived")
    failed = 0
    regressions = 0
    coverage_holes = 0
    repeats = max(1, args.repeats) if args.trajectory else 1
    for name, mod in modules:
        if args.only and args.only not in name:
            continue
        before = _bench_snapshot() if args.trajectory else None
        mtimes = {p: os.stat(p).st_mtime for p in glob.glob("BENCH_*.json")} \
            if args.trajectory else {}
        samples_by_path: dict = {}
        try:
            for rep in range(repeats):
                rows = mod.run()
                if rep == 0:
                    for row_name, value, note in rows:
                        print(f"{row_name},{value:.4g},{note}")
                if args.trajectory:
                    # gate only the files THIS module (re)wrote
                    for path, leaves in _read_bench().items():
                        st = os.stat(path).st_mtime
                        if path in mtimes and st == mtimes[path]:
                            continue
                        store = samples_by_path.setdefault(path, {})
                        for key, val in leaves.items():
                            store.setdefault(key, []).append(val)
        except Exception as e:   # noqa: BLE001
            print(f"{name},ERROR,{e!r}")
            failed += 1
        if args.trajectory and samples_by_path:
            regressions += _trajectory_report(before, samples_by_path)
            holes = _coverage_problems(
                {p: {k: _median(v) for k, v in s.items()}
                 for p, s in samples_by_path.items()})
            for h in holes:
                print(f"# trajectory: coverage: {h}")
            coverage_holes += len(holes)
    if args.trajectory and regressions:
        print(f"# trajectory: {regressions} confirmed regression(s) "
              f"beyond the noise band")
    if args.trajectory and coverage_holes:
        print(f"# trajectory: {coverage_holes} BENCH leaf/leaves with no "
              f"declared kernel coverage")
    sys.exit(1 if (failed or regressions or coverage_holes) else 0)


if __name__ == "__main__":
    main()
