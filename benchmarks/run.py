"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig8] [--trajectory]

Prints ``name,value,derived`` CSV (value is µs for *_us rows, else a
dimensionless/derived quantity per the row's note).

``--trajectory`` is the first step of the ROADMAP perf-regression
harness: before each module runs, the previous ``BENCH_*.json`` payloads
are snapshotted (the committed version via ``git show`` when one exists,
else the working-tree file from the last run); after the module, every
numeric leaf of any BENCH file it rewrote is compared and the per-metric
deltas printed — ``WARN``-flagged when a metric moved more than 20%
run-over-run. Wall-clock metrics are expected to jitter; the flag is a
prompt to look, not a failure (the process still exits 0 unless a module
raised).
"""

import argparse
import glob
import json
import subprocess
import sys

REGRESSION_FRAC = 0.20


def _numeric_leaves(obj, prefix=""):
    """Flatten a JSON payload to {dotted.path: float} over numeric leaves."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_numeric_leaves(v, f"{prefix}{k}."))
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            out.update(_numeric_leaves(v, f"{prefix}{i}."))
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        out[prefix.rstrip(".")] = float(obj)
    return out


def _bench_snapshot():
    """{filename: numeric leaves} of every BENCH_*.json — the committed
    version when git has one (the run-over-run reference), else the
    working-tree file left by the previous run."""
    snap = {}
    for path in sorted(glob.glob("BENCH_*.json")):
        text = None
        try:
            text = subprocess.run(
                ["git", "show", f"HEAD:{path}"], capture_output=True,
                text=True, check=True).stdout
        except (subprocess.CalledProcessError, OSError):
            pass
        if text is None:
            try:
                with open(path) as fh:
                    text = fh.read()
            except OSError:
                continue
        try:
            snap[path] = _numeric_leaves(json.loads(text))
        except (ValueError, TypeError):
            continue
    return snap


def _trajectory_report(before: dict) -> int:
    """Compare fresh BENCH payloads against ``before``; print deltas,
    return the count of >20% moves."""
    moved = 0
    for path in sorted(glob.glob("BENCH_*.json")):
        try:
            with open(path) as fh:
                fresh = _numeric_leaves(json.load(fh))
        except (OSError, ValueError):
            continue
        prev = before.get(path)
        if prev is None:
            print(f"# trajectory: {path} is new (no previous run)")
            continue
        if prev == fresh:
            continue
        for key in sorted(set(prev) & set(fresh)):
            a, b = prev[key], fresh[key]
            if a == b:
                continue
            rel = abs(b - a) / max(abs(a), 1e-12)
            flag = " WARN" if rel > REGRESSION_FRAC else ""
            if flag:
                moved += 1
            print(f"# trajectory: {path}:{key} {a:.4g} -> {b:.4g} "
                  f"({'+' if b >= a else '-'}{rel * 100:.1f}%){flag}")
        for key in sorted(set(fresh) - set(prev)):
            print(f"# trajectory: {path}:{key} (new) = {fresh[key]:.4g}")
        for key in sorted(set(prev) - set(fresh)):
            print(f"# trajectory: {path}:{key} dropped "
                  f"(was {prev[key]:.4g})")
    return moved


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module name")
    ap.add_argument("--trajectory", action="store_true",
                    help="after each module, diff its fresh BENCH_*.json "
                         "against the previous run's and warn on >20% "
                         "metric moves")
    args = ap.parse_args()

    from benchmarks import (fig8_lop, fig9_schedule, kernels_micro,
                            prefill_interleave, prefix_cache, spec_decode,
                            table1_e2e)
    modules = [
        ("fig8_lop", fig8_lop),
        ("fig9_schedule", fig9_schedule),
        ("table1_e2e", table1_e2e),
        ("kernels_micro", kernels_micro),
        ("prefill_interleave", prefill_interleave),
        ("prefix_cache", prefix_cache),
        ("spec_decode", spec_decode),
    ]
    print("name,value,derived")
    failed = 0
    warned = 0
    for name, mod in modules:
        if args.only and args.only not in name:
            continue
        before = _bench_snapshot() if args.trajectory else None
        try:
            for row_name, value, note in mod.run():
                print(f"{row_name},{value:.4g},{note}")
        except Exception as e:   # noqa: BLE001
            print(f"{name},ERROR,{e!r}")
            failed += 1
        if args.trajectory:
            warned += _trajectory_report(before)
    if args.trajectory and warned:
        print(f"# trajectory: {warned} metric(s) moved more than "
              f"{REGRESSION_FRAC:.0%} run-over-run")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
