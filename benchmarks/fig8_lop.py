"""Fig. 8 reproduction: effect of LOP on MHA throughput and KV-cache traffic.

Paper claims (BitNet-3B silicon): KV traffic ↓54.86×, MHA throughput
+26.31%. The traffic claim counts off-chip K/V fetches only (the 4-bit
feature cache lives on-chip in the 120 KB SRAM); we report both conventions:

  * ``traffic_kv_only``      — 2·M·d  →  2·K·d          (paper's convention)
  * ``traffic_with_screen``  — 2·M·d  →  M·d/2 + 2·K·d  (HBM-resident
                               features, the TPU deployment reality)

Throughput is measured on CPU semantics (dense int8 decode attention vs the
LOP screen → select → sparse path) — directionally validating the claim;
the silicon ratio depends on the ASIC's memory system.

Fused-vs-legacy dispatch
------------------------
The decode stack used to launch ``lop_screen`` and ``sparse_decode`` as
separate single-kv-head kernels under a triple ``vmap`` over (batch,
kv-head, group) with the block selector in plain jnp between them; it is
now ONE batched kernel (``ops.decode_attention``). This benchmark keeps a
local copy of the legacy dispatch and reports both step costs plus the
Pallas call-site count of each path (from the jaxpr — interpret-mode
lowering on CPU emits no ``custom-call``s to count in HLO, so the jaxpr
equation count is the portable proxy; each site is a separate kernel
launch boundary with jnp glue round-tripping through HBM between them).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lop import kv_traffic_bytes
from repro.serving.engine import lop_decode_attention
from repro.serving.lop_select import k_keep_blocks, select_blocks

from repro.configs.bitnet_3b import REDUCED as BITNET_REDUCED


def _time(fn, *args, iters=20):
    fn(*args)                                   # compile
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6     # µs


def _legacy_vmap_decode(cfg, qi, qsc, cl, new_len):
    """The pre-fusion dispatch: per-head small kernels under a triple vmap.

    Kept verbatim (paper-faithful per-q-head selection) as the baseline the
    fused kernel replaced — screen kernel, jnp block top-K, then one
    ``sparse_decode`` launch per (batch, kv-head, group) lane.
    """
    from repro.kernels import ops
    b, h, dh = qi.shape
    hkv = cl["k"].shape[1]
    g = h // hkv
    m = cl["k"].shape[2]
    sm = dh ** -0.5
    block = cfg.lop_block
    k_keep = k_keep_blocks(cfg, m)
    qg = qi.reshape(b, hkv, g, dh)
    screen = jax.vmap(jax.vmap(ops.lop_screen))          # over (B, Hkv)
    scores = screen(qg, cl["feat"])                      # [B, Hkv, G, M]
    idx, gate_tokens = select_blocks(scores, new_len, block=block,
                                     k_keep=k_keep, window=0)
    qsc_g = qsc.reshape(b, hkv, g)

    def one(qv, qs, kc, vc, ks, vs, bi, gt):
        return ops.sparse_decode(qv[None], kc, vc, qs.reshape(1, 1),
                                 ks[:, None], vs[:, None], bi, gt,
                                 block=block, softmax_scale=sm)[0]

    per_g = jax.vmap(one, in_axes=(0, 0, None, None, None, None, 0, 0))
    per_b = jax.vmap(jax.vmap(per_g))
    out = per_b(qg, qsc_g, cl["k"], cl["v"], cl["k_scale"], cl["v_scale"],
                idx, gate_tokens)                        # [B, Hkv, G, dh]
    return out.reshape(b, h, dh)


def _pallas_call_sites(fn, *args) -> int:
    """Pallas kernel call sites in ``fn``'s jaxpr (launch boundaries)."""
    return str(jax.make_jaxpr(fn)(*args)).count("pallas_call")


def run():
    # paper setting: BitNet-3B-like head_dim, decode against an M-token cache
    cfg = BITNET_REDUCED.replace(lop_keep=1 / 54.86, lop_block=32,
                                 gqa_shared_select=False, int8_logits=False)
    b, h, dh, m = 4, cfg.n_heads, cfg.hd, 2048
    hkv = cfg.n_kv_heads
    rng = np.random.default_rng(0)
    qi = jnp.asarray(rng.integers(-80, 81, (b, h, dh)), jnp.int8)
    qsc = jnp.asarray(rng.uniform(0.005, 0.02, (b, h, 1)), jnp.float32)
    cl = {
        "k": jnp.asarray(rng.integers(-80, 81, (b, hkv, m, dh)), jnp.int8),
        "v": jnp.asarray(rng.integers(-80, 81, (b, hkv, m, dh)), jnp.int8),
        "k_scale": jnp.asarray(rng.uniform(0.005, 0.02, (b, hkv, m)),
                               jnp.float32),
        "v_scale": jnp.asarray(rng.uniform(0.005, 0.02, (b, hkv, m)),
                               jnp.float32),
    }
    from repro.core.lop import lop_features, pack_features
    cl["feat"] = pack_features(lop_features(cl["k"]))
    new_len = jnp.full((b,), m, jnp.int32)

    dense = jax.jit(lambda q, qs, c, n: lop_decode_attention(
        cfg, q, qs, c, n, window=0, use_lop=False))
    sparse = jax.jit(lambda q, qs, c, n: lop_decode_attention(
        cfg, q, qs, c, n, window=0, use_lop=True))
    legacy = jax.jit(lambda q, qs, c, n: _legacy_vmap_decode(
        cfg, q, qs, c, n))

    t_dense = _time(dense, qi, qsc, cl, new_len)
    t_sparse = _time(sparse, qi, qsc, cl, new_len)
    t_legacy = _time(legacy, qi, qsc, cl, new_len)

    # kernel call sites of each dispatch (impl="pallas" jaxprs); the fused
    # path is ONE pallas_call spanning every (batch, kv-head) lane, the
    # legacy path is a screen launch + a sparse launch per head group with
    # jnp selection glue between them
    import os
    prev_impl = os.environ.get("REPRO_KERNEL_IMPL")
    os.environ["REPRO_KERNEL_IMPL"] = "pallas"
    try:
        sites_fused = _pallas_call_sites(
            lambda q: lop_decode_attention(cfg, q, qsc, cl, new_len,
                                           window=0, use_lop=True), qi)
        sites_legacy = _pallas_call_sites(
            lambda q: _legacy_vmap_decode(cfg, q, qsc, cl, new_len), qi)
    finally:
        if prev_impl is None:
            del os.environ["REPRO_KERNEL_IMPL"]
        else:
            os.environ["REPRO_KERNEL_IMPL"] = prev_impl

    k_tokens = max(1, int(round(cfg.lop_keep * (m // cfg.lop_block)))) \
        * cfg.lop_block
    kv_only_dense = 2 * m * dh
    kv_only_lop = 2 * k_tokens * dh
    with_screen_lop = kv_traffic_bytes(m, dh, k_tokens, with_lop=True)

    rows = [
        ("fig8/mha_dense_us", t_dense, "dense int8 decode attention"),
        ("fig8/mha_lop_us", t_sparse,
         f"fused LOP screen+topk+sparse (keep={cfg.lop_keep:.4f})"),
        ("fig8/mha_speedup", t_dense / t_sparse,
         "paper: +26.31% (1.26x)"),
        ("fig8/decode_legacy_vmap_us", t_legacy,
         "pre-fusion dispatch: per-head vmap'd screen+select+sparse"),
        ("fig8/decode_fused_vs_legacy", t_legacy / t_sparse,
         "fused single-kernel step cost vs legacy per-head dispatch"),
        ("fig8/kernel_call_sites_fused", sites_fused,
         "pallas_call sites in the fused decode jaxpr (target: 1)"),
        ("fig8/kernel_call_sites_legacy", sites_legacy,
         "pallas_call sites in the legacy decode jaxpr (screen + sparse)"),
        ("fig8/kv_traffic_reduction_kv_only", kv_only_dense / kv_only_lop,
         "paper convention (features on-chip): target 54.86x"),
        ("fig8/kv_traffic_reduction_with_screen",
         kv_only_dense / with_screen_lop,
         "HBM-resident feature cache (TPU deployment)"),
        ("fig8/keep_fraction", cfg.lop_keep, "K/M"),
    ]
    return rows
