"""Fig. 8 reproduction: effect of LOP on MHA throughput and KV-cache traffic.

Paper claims (BitNet-3B silicon): KV traffic ↓54.86×, MHA throughput
+26.31%. The traffic claim counts off-chip K/V fetches only (the 4-bit
feature cache lives on-chip in the 120 KB SRAM); we report both conventions:

  * ``traffic_kv_only``      — 2·M·d  →  2·K·d          (paper's convention)
  * ``traffic_with_screen``  — 2·M·d  →  M·d/2 + 2·K·d  (HBM-resident
                               features, the TPU deployment reality)

Throughput is measured on CPU semantics (dense int8 decode attention vs the
LOP screen → select → sparse path) — directionally validating the claim;
the silicon ratio depends on the ASIC's memory system.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lop import kv_traffic_bytes
from repro.models.transformer import init_params
from repro.serving.engine import lop_decode_attention
from repro.serving.quantize import quantize_params

from repro.configs.bitnet_3b import REDUCED as BITNET_REDUCED


def _time(fn, *args, iters=20):
    fn(*args)                                   # compile
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6     # µs


def run():
    # paper setting: BitNet-3B-like head_dim, decode against an M-token cache
    cfg = BITNET_REDUCED.replace(lop_keep=1 / 54.86, lop_block=32)
    b, h, dh, m = 4, cfg.n_heads, cfg.hd, 2048
    hkv = cfg.n_kv_heads
    rng = np.random.default_rng(0)
    qi = jnp.asarray(rng.integers(-80, 81, (b, h, dh)), jnp.int8)
    qsc = jnp.asarray(rng.uniform(0.005, 0.02, (b, h, 1)), jnp.float32)
    cl = {
        "k": jnp.asarray(rng.integers(-80, 81, (b, hkv, m, dh)), jnp.int8),
        "v": jnp.asarray(rng.integers(-80, 81, (b, hkv, m, dh)), jnp.int8),
        "k_scale": jnp.asarray(rng.uniform(0.005, 0.02, (b, hkv, m)),
                               jnp.float32),
        "v_scale": jnp.asarray(rng.uniform(0.005, 0.02, (b, hkv, m)),
                               jnp.float32),
    }
    from repro.core.lop import lop_features, pack_features
    cl["feat"] = pack_features(lop_features(cl["k"]))
    new_len = jnp.full((b,), m, jnp.int32)

    dense = jax.jit(lambda q, qs, c, n: lop_decode_attention(
        cfg, q, qs, c, n, window=0, use_lop=False))
    sparse = jax.jit(lambda q, qs, c, n: lop_decode_attention(
        cfg, q, qs, c, n, window=0, use_lop=True))

    t_dense = _time(dense, qi, qsc, cl, new_len)
    t_sparse = _time(sparse, qi, qsc, cl, new_len)

    k_tokens = max(1, int(round(cfg.lop_keep * (m // cfg.lop_block)))) \
        * cfg.lop_block
    kv_only_dense = 2 * m * dh
    kv_only_lop = 2 * k_tokens * dh
    with_screen_lop = kv_traffic_bytes(m, dh, k_tokens, with_lop=True)

    rows = [
        ("fig8/mha_dense_us", t_dense, "dense int8 decode attention"),
        ("fig8/mha_lop_us", t_sparse,
         f"LOP screen+topk+sparse (keep={cfg.lop_keep:.4f})"),
        ("fig8/mha_speedup", t_dense / t_sparse,
         "paper: +26.31% (1.26x)"),
        ("fig8/kv_traffic_reduction_kv_only", kv_only_dense / kv_only_lop,
         "paper convention (features on-chip): target 54.86x"),
        ("fig8/kv_traffic_reduction_with_screen",
         kv_only_dense / with_screen_lop,
         "HBM-resident feature cache (TPU deployment)"),
        ("fig8/keep_fraction", cfg.lop_keep, "K/M"),
    ]
    return rows
