"""Chunked-prefill interleaving ablation (DESIGN.md §Chunked-prefill).

Staggered mixed-length traffic over a 2-lane pool, served twice:

  * **chunked** — the scheduler advances ONE fixed-shape prefill chunk
    per serve cycle, interleaved with the running decode batch; a long
    prompt admitted mid-run never stalls the other lane's decoding
    (``interleaved_decode_steps`` > 0, ``full_prefill_stalls`` == 0).
  * **run-to-completion** — the pre-chunking baseline: admission runs the
    whole prompt's prefill while active lanes wait
    (``full_prefill_stalls`` counts those whole-prompt waits), and
    prefill compiles scale with the pow2 length buckets instead of one
    chunk shape.

Reported: TTFT p50/p99 per mode, decode steps taken while a prompt was
mid-prefill, whole-prompt stall events, prefill compile counts, and
aggregate tokens/s. On CPU the absolute times are compile-dominated; the
structural rows (stalls, interleaved steps, compiles) are the claim.
"""

from __future__ import annotations


def _serve(chunked: bool):
    from repro.configs.bitnet_3b import REDUCED
    from repro.launch.serve import serve_loop

    # prompts up to 48 tokens vs gen 12: the long prompts prefill across
    # multiple cycles while short requests decode in the other lane
    return serve_loop(REDUCED, n_slots=2, n_requests=6, min_prompt=6,
                      max_prompt=48, gen=12, seed=0, chunked=chunked)


def run():
    from repro.serving.metrics import percentile

    out_c = _serve(chunked=True)
    out_l = _serve(chunked=False)
    assert out_c["interleaved_decode_steps"] > 0, \
        "chunked run took no decode steps during a prefill"
    assert out_c["full_prefill_stalls"] == 0, \
        "chunked run stalled a full batch behind a prompt"
    # same greedy tokens either way — interleaving is pure scheduling
    for rid, toks in out_c["tokens"].items():
        assert list(toks) == list(out_l["tokens"][rid]), rid
    # TTFT tails straight off the raw per-request series via the shared
    # percentile helper (same interpolation serve_loop's summaries use)
    ttft_c = [r.ttft for r in out_c["results"]]
    ttft_l = [r.ttft for r in out_l["results"]]
    return [
        ("prefill_interleave/ttft_p50_ms_chunked",
         percentile(ttft_c, 50) * 1e3, "TTFT under interleaving"),
        ("prefill_interleave/ttft_p50_ms_run_to_completion",
         percentile(ttft_l, 50) * 1e3, "TTFT with whole-prompt stalls"),
        ("prefill_interleave/ttft_p99_ms_chunked",
         percentile(ttft_c, 99) * 1e3, "tail TTFT under interleaving"),
        ("prefill_interleave/ttft_p99_ms_run_to_completion",
         percentile(ttft_l, 99) * 1e3, "tail TTFT with stalls"),
        ("prefill_interleave/decode_steps_mid_prefill_chunked",
         out_c["interleaved_decode_steps"],
         "decode progress while a prompt prefilled (>0 = no lane stall)"),
        ("prefill_interleave/decode_steps_mid_prefill_run_to_completion",
         out_l["interleaved_decode_steps"], "baseline (always 0)"),
        ("prefill_interleave/full_prefill_stalls_chunked",
         out_c["full_prefill_stalls"], "whole-prompt waits (0 = claim)"),
        ("prefill_interleave/full_prefill_stalls_run_to_completion",
         out_l["full_prefill_stalls"], "whole-prompt waits of baseline"),
        ("prefill_interleave/prefill_compiles_chunked",
         out_c["prefill_compiles"], "one fixed chunk shape"),
        ("prefill_interleave/prefill_compiles_run_to_completion",
         out_l["prefill_compiles"], "one per pow2 length bucket"),
        ("prefill_interleave/tokens_per_s_chunked",
         out_c["tokens_per_s"], "aggregate throughput"),
        ("prefill_interleave/tokens_per_s_run_to_completion",
         out_l["tokens_per_s"], "aggregate throughput"),
    ]
