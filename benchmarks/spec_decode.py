"""Self-speculative decoding ablation: draft cheap, verify in one chunk.

Replays ONE deterministic single-lane trace (4 requests, greedy, LOP on)
under speculative decoding at γ ∈ {2, 4, 8} against the plain-decode
baseline, for two draft configurations sharing the serving stack's
weights (DESIGN.md §Speculative-decoding):

  * **truncated stack** — the draft runs ``draft_layers=2`` of the 3
    reduced layers with the LOP selection pinched to 1 block; the
    cheapest proposer, lowest agreement.
  * **lop-only** — the draft runs the FULL stack but keeps only 1 LOP
    block per head; agreement comes almost entirely from the screen's
    fidelity, so this bounds what the 4-bit feature cache alone buys.

The accounting is per-lane (``n_slots=1``) so batching cannot mask the
speculative win: a *decoded* token (everything after the prefill-seeded
first token) costs exactly 1.0 full-model launches at baseline; with
speculation it costs ``(decode + verify launches) / decoded`` — strictly
< 1.0 exactly when verify accepts drafts. Draft passes are counted
separately, weighted by their layer fraction, into a total model-step
equivalence. The raw series goes to ``BENCH_spec.json``. On CPU the
wall-clock is noise; the launch accounting is the claim.
"""

from __future__ import annotations

import json

N_REQUESTS = 4
GEN = 12
GAMMAS = (2, 4, 8)
N_LAYERS = 3          # reduced bitnet layer count (layer-fraction math)
ARMS = {
    "truncated": {"draft_layers": 2, "draft_k": 1},
    "lop_only": {"draft_layers": 3, "draft_k": 1},
}


def _engine(draft_layers: int, draft_k: int):
    from repro.configs.bitnet_3b import REDUCED
    from repro.models.transformer import init_params
    from repro.serving.api import PooledEngine
    from repro.serving.quantize import quantize_params
    import jax

    cfg = REDUCED
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    return cfg, PooledEngine(cfg, qp, max_len=24 + GEN,
                             draft_layers=draft_layers, draft_k=draft_k)


def _serve(engine, *, spec: bool, gamma: int = 4, seed: int = 0):
    from repro.launch.serve import serve_loop

    return serve_loop(None, n_slots=1, n_requests=N_REQUESTS, min_prompt=8,
                      max_prompt=24, gen=GEN, seed=seed, prefix_cache=False,
                      spec_decode=spec, gamma=gamma, engine=engine)


def _account(out):
    decoded = sum(len(t) for t in out["tokens"].values()) - N_REQUESTS
    full = out["decode_launches"] + out["spec_verify_launches"]
    draft_frac = out["draft_launches"] / max(1, decoded)
    return {
        "decoded_tokens": decoded,
        "full_launches": full,
        "full_launches_per_decoded": full / max(1, decoded),
        "draft_launches_per_decoded": draft_frac,
        "accept_rate": out["spec_accept_rate"],
        "tokens_per_verify": out["spec_tokens_per_verify"],
        "spec_rounds": out["spec_rounds"],
        "wall_s": out["wall_s"],
    }


def run():
    rows = []
    payload = {"trace": {"n_requests": N_REQUESTS, "gen": GEN,
                         "n_slots": 1, "gammas": list(GAMMAS)},
               "arms": {}}

    for arm, knobs in ARMS.items():
        cfg, engine = _engine(**knobs)
        payload["trace"]["arch"] = cfg.name
        # warmup compiles (prefill/decode/draft/verify shapes)
        _serve(engine, spec=True, gamma=GAMMAS[0], seed=9)

        base = _account(_serve(engine, spec=False))
        arm_out = {"draft_layers": knobs["draft_layers"],
                   "draft_k": knobs["draft_k"], "baseline": base,
                   "gammas": {}}
        assert base["full_launches_per_decoded"] == 1.0, (
            "baseline accounting must be exactly one full-model launch "
            f"per decoded token, got {base['full_launches_per_decoded']}")

        for g in GAMMAS:
            acc = _account(_serve(engine, spec=True, gamma=g))
            # the draft's layer-fraction cost folded in: total model-step
            # equivalents per decoded token
            acc["model_step_equiv_per_decoded"] = (
                acc["full_launches_per_decoded"]
                + acc["draft_launches_per_decoded"]
                * knobs["draft_layers"] / N_LAYERS)
            acc["full_launches_saved_vs_baseline"] = (
                1.0 - acc["full_launches_per_decoded"])
            arm_out["gammas"][g] = acc
        payload["arms"][arm] = arm_out

        for g in GAMMAS:
            acc = arm_out["gammas"][g]
            rows += [
                (f"spec_decode/{arm}/g{g}/accept_rate", acc["accept_rate"],
                 "accepted drafts / drafted"),
                (f"spec_decode/{arm}/g{g}/tokens_per_verify",
                 acc["tokens_per_verify"],
                 "tokens emitted per verify launch (accepted prefix + "
                 "bonus)"),
                (f"spec_decode/{arm}/g{g}/full_launches_per_decoded",
                 acc["full_launches_per_decoded"],
                 "full-model launches per decoded token (< 1.0 = win)"),
                (f"spec_decode/{arm}/g{g}/model_step_equiv_per_decoded",
                 acc["model_step_equiv_per_decoded"],
                 "with draft cost at its layer fraction"),
            ]

    # acceptance bar: the truncated-stack draft at γ=4 accepts something
    # and amortizes full-model launches below one per decoded token
    g4 = payload["arms"]["truncated"]["gammas"][4]
    assert g4["accept_rate"] > 0, (
        f"truncated-stack draft accepted nothing at γ=4: {g4}")
    assert g4["full_launches_per_decoded"] < 1.0, (
        f"speculation did not amortize launches at γ=4: {g4}")

    with open("BENCH_spec.json", "w") as fh:
        json.dump(payload, fh, indent=2)
    return rows
