"""End-to-end driver: QAT-train a ~100M-param BitNet b1.58 model for a few
hundred steps on the synthetic pipeline, with checkpoint/restart.

    PYTHONPATH=src python examples/train_bitnet.py [--steps 300]

This is the brief's "train ~100M model for a few hundred steps" e2e driver.
The config is a scaled BitNet (12L, d=768) — every projection a BitLinear
trained with STE; loss decreasing proves the QAT flow learns through the
ternary forward.
"""
import argparse

import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.fault_tolerance import PreemptionHandler
from repro.launch.train import train_loop

CFG_100M = ModelConfig(
    name="bitnet-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=2048,
    vocab=8192,
    head_dim=64,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/bitnet100m_ckpt")
    args = ap.parse_args()

    n_params = (CFG_100M.vocab_padded * CFG_100M.d_model * 2
                + CFG_100M.n_layers * (4 * CFG_100M.d_model ** 2
                                       + 3 * CFG_100M.d_model * CFG_100M.d_ff))
    print(f"training {CFG_100M.name}: ~{n_params/1e6:.0f}M params, "
          f"{args.steps} steps, batch {args.global_batch} × seq {args.seq}")
    out = train_loop(
        CFG_100M, steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=100,
        peak_lr=6e-4, preemption=PreemptionHandler())
    first, last = np.mean(out["losses"][:10]), np.mean(out["losses"][-10:])
    print(f"loss {first:.3f} → {last:.3f}; "
          f"straggler summary: {out['straggler']}")
    assert last < first, "QAT did not learn"
    print("OK — checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
