"""Serve a small model with batched requests, ablating the LOP screen.

    PYTHONPATH=src python examples/serve_lop.py [--arch mistral-nemo-12b]

Part 1 runs the same batch with (a) dense int8 decode attention and (b) LOP
predictive sparse attention at several keep fractions, reporting decode
wall time and the modeled KV traffic — the serving-side view of Fig. 8.
Part 2 pushes a mixed-prompt-length request stream through the slot-paged
continuous-batching scheduler and checks every request against its solo
lockstep run — the serving-engine view of the same screen.
Part 3 exercises the typed serving API (DESIGN.md §Serving-API): seeded
temperature/top-k sampling through the continuous-batching pool, with
every request verified token-identical against its lockstep replay and
inter-token-latency percentiles reported.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lop import kv_traffic_bytes
from repro.launch.serve import serve_loop
from repro.launch.train import resolve_config
from repro.models.transformer import init_params
from repro.serving.engine import prefill, serve_step
from repro.serving.quantize import quantize_params


def run(cfg, qp, prompts, gen, use_lop):
    step = jax.jit(lambda qp, c, t: serve_step(cfg, qp, c, t,
                                               use_lop=use_lop),
                   donate_argnums=(1,))
    logits, cache = prefill(cfg, qp, prompts,
                            max_len=prompts.shape[1] + gen,
                            use_lop=use_lop)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    toks = []
    t0 = time.time()
    for _ in range(gen):
        toks.append(np.asarray(tok))
        logits, cache = step(qp, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    return np.concatenate(toks, 1), time.time() - t0


def keep_ablation(base, qp, args):
    rng = np.random.default_rng(1)
    prompts = jnp.asarray(rng.integers(0, base.vocab,
                                       (args.batch, args.prompt_len)),
                          jnp.int32)
    m = args.prompt_len + args.gen
    ref_toks, t_dense = run(base, qp, prompts, args.gen, use_lop=False)
    print(f"dense decode:            {t_dense:.2f}s")
    for keep in (1.0, 0.5, 0.25):
        cfg = base.replace(lop_keep=keep)
        toks, t = run(cfg, qp, prompts, args.gen, use_lop=True)
        agree = float((toks == ref_toks).mean())
        traffic = kv_traffic_bytes(m, cfg.hd, int(keep * m), with_lop=True)
        dense_traffic = kv_traffic_bytes(m, cfg.hd, m, with_lop=False)
        print(f"LOP keep={keep:4.2f} decode:  {t:.2f}s  "
              f"token agreement {agree:5.1%}  "
              f"KV traffic ÷{dense_traffic / traffic:.1f}")


def continuous_batching_demo(cfg, args):
    """Slot-paged scheduler over mixed prompt lengths + solo cross-check
    (the full driver: serve_loop handles traffic synthesis and the
    per-request lockstep replay)."""
    out = serve_loop(cfg, n_slots=args.batch, n_requests=args.batch * 2,
                     min_prompt=max(args.prompt_len // 4, 4),
                     max_prompt=args.prompt_len, gen=args.gen, verify=True)
    agree = len(out["results"]) - len(out["mismatched_rids"])
    print(f"continuous batching: {len(out['results'])} reqs on "
          f"{args.batch} lanes, {out['wall_s']:.2f}s wall, "
          f"{out['prefill_compiles']} prefill bucket compiles")
    print(f"  lockstep agreement {agree}/{len(out['results'])}; latency "
          f"p50 {out['latency_p50'] * 1e3:.0f} ms, p99 "
          f"{out['latency_p99'] * 1e3:.0f} ms")


def sampled_api_demo(cfg, args):
    """Typed-API part: per-request SamplingParams through the pool, each
    request verified against its same-seed lockstep replay."""
    from repro.serving.api import SamplingParams
    out = serve_loop(cfg, n_slots=args.batch, n_requests=args.batch,
                     min_prompt=max(args.prompt_len // 4, 4),
                     max_prompt=args.prompt_len, gen=args.gen, verify=True,
                     sampling=SamplingParams(temperature=0.8, top_k=8,
                                             seed=7))
    agree = len(out["results"]) - len(out["mismatched_rids"])
    print(f"sampled serving (T=0.8 top_k=8, per-request seeds): "
          f"{agree}/{len(out['results'])} pool == same-seed lockstep; "
          f"itl p50 {out['itl_p50'] * 1e3:.0f} ms, p99 "
          f"{out['itl_p99'] * 1e3:.0f} ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    base = resolve_config(args.arch, reduced=True)
    params, _ = init_params(base, jax.random.PRNGKey(0))
    qp = quantize_params(base, params)
    keep_ablation(base, qp, args)
    continuous_batching_demo(base, args)
    sampled_api_demo(base, args)


if __name__ == "__main__":
    main()
