"""Quickstart: the paper's pipeline in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Build a small BitNet-style model (any of the 10 assigned archs works:
   swap the config import).
2. Quantize to the deployment format: packed 2-bit ternary weights (TINT
   stream) + absmax int8 activations.
3. Prefill with int8 flash attention, then decode with LOP predictive
   sparse attention (screen → comparison-free top-K → exact attention on
   the K candidate blocks).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bitnet_3b import REDUCED as CFG
from repro.core.lop import kv_traffic_bytes
from repro.models.transformer import init_params
from repro.serving.engine import prefill, serve_step
from repro.serving.quantize import quantize_params


def main():
    cfg = CFG.replace(lop_keep=0.25)          # keep 25% of KV blocks
    print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model}) "
          f"quant={cfg.quant} lop_keep={cfg.lop_keep}")

    # 1. init master weights, 2. convert to deployment format
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(cfg, params)
    wqkv = qp["layers"]["attn"]["wqkv"]
    print(f"QKV deployed as ONE packed uint8 {wqkv['packed'].shape} "
          f"(2 bit/weight, fused at quantize time) + per-column γ")

    # 3. serve a batch of prompts
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (4, 24)), jnp.int32)
    logits, cache = prefill(cfg, qp, prompts, max_len=24 + 16)
    print(f"prefill done: cache holds {int(cache['lengths'][0])} tokens "
          f"(int8 K/V + f32 scales + packed 4-bit LOP features)")

    generated = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for _ in range(16):
        generated.append(np.asarray(tok))
        logits, cache = serve_step(cfg, qp, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = np.concatenate(generated, axis=1)
    print("greedy continuation:\n", out)

    m = int(cache["lengths"][0])
    dense = kv_traffic_bytes(m, cfg.hd, m, with_lop=False)
    lop = kv_traffic_bytes(m, cfg.hd, int(cfg.lop_keep * m), with_lop=True)
    print(f"KV bytes/head/query: {dense} dense → {lop} with LOP "
          f"({dense / lop:.1f}×; paper's Fig. 8 regime counts only exact "
          f"K/V fetches: {dense / (2 * int(cfg.lop_keep * m) * cfg.hd):.1f}×)")


if __name__ == "__main__":
    main()
